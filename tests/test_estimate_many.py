"""Property tests for the batched estimation fast path (core/estimator.py).

The contract: ``estimate_many`` runs the same §III pipeline as per-config
``estimate`` through cached, vectorized primitives — results must agree
*bit-for-bit* (not approximately) over randomized stencil25 / LBM
configurations on every machine model, with and without a shared
:class:`EstimateCache`, for both footprint methods.
"""
from __future__ import annotations

import dataclasses

import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency; pip install -r requirements-dev.txt")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import appspec, estimator
from repro.core.bankconflict import block_l1_cycles, block_l1_cycles_fast
from repro.core.footprint import warp_requested_bytes, warp_requested_bytes_fast
from repro.core.machine import A100_40GB, V100
from repro.core.waves import interior_block_box

GRID = (128, 64, 64)  # reduced grid keeps each full estimate cheap

STENCIL_CFGS = appspec.stencil_config_space()
LBM_CFGS = appspec.lbm_config_space()

machines = st.sampled_from([V100, A100_40GB])
stencil_picks = st.lists(
    st.sampled_from(STENCIL_CFGS), min_size=1, max_size=4, unique_by=str
)
lbm_picks = st.lists(st.sampled_from(LBM_CFGS), min_size=1, max_size=4, unique_by=str)


def _specs(build, cfgs):
    return [build(block=c["block"], fold=c["fold"], grid=GRID) for c in cfgs]


def _assert_bitwise_equal(ref, got):
    for r, g in zip(ref, got):
        assert dataclasses.asdict(r) == dataclasses.asdict(g)


@given(stencil_picks, machines)
@settings(max_examples=25, deadline=None)
def test_stencil_batch_equals_per_config_bitwise(cfgs, machine):
    specs = _specs(appspec.star3d, cfgs)
    ref = [estimator.estimate(s, machine, method="sym") for s in specs]
    _assert_bitwise_equal(ref, estimator.estimate_many(specs, machine, method="sym"))


@given(lbm_picks, machines)
@settings(max_examples=25, deadline=None)
def test_lbm_batch_equals_per_config_bitwise(cfgs, machine):
    specs = _specs(appspec.lbm_d3q15, cfgs)
    ref = [estimator.estimate(s, machine, method="sym") for s in specs]
    _assert_bitwise_equal(ref, estimator.estimate_many(specs, machine, method="sym"))


@given(stencil_picks)
@settings(max_examples=10, deadline=None)
def test_enum_method_batch_equals_per_config_bitwise(cfgs):
    specs = _specs(appspec.star3d, cfgs)
    ref = [estimator.estimate(s, V100, method="enum") for s in specs]
    _assert_bitwise_equal(ref, estimator.estimate_many(specs, V100, method="enum"))


@given(stencil_picks)
@settings(max_examples=10, deadline=None)
def test_shared_cache_across_machines_stays_bitwise(cfgs):
    """One cache serving several machines (the crossmachine.compare pattern)
    must never leak one machine's sub-results into another's estimates."""
    specs = _specs(appspec.star3d, cfgs)
    cache = estimator.EstimateCache()
    for machine in (V100, A100_40GB):
        ref = [estimator.estimate(s, machine, method="sym") for s in specs]
        got = estimator.estimate_many(specs, machine, method="sym", cache=cache)
        _assert_bitwise_equal(ref, got)
    # the second machine reused at least the machine-independent L1 block work
    assert cache.hits > 0


@given(st.sampled_from(STENCIL_CFGS))
@settings(max_examples=30, deadline=None)
def test_fast_l1_primitives_match_reference(cfg):
    spec = appspec.star3d(block=cfg["block"], fold=cfg["fold"], grid=GRID)
    blk = interior_block_box(spec.launch)
    assert block_l1_cycles_fast(spec.accesses, blk) == block_l1_cycles(
        spec.accesses, blk
    )
    for stores in (False, True):
        assert warp_requested_bytes_fast(
            spec.accesses, blk, 32, stores=stores
        ) == warp_requested_bytes(spec.accesses, blk, 32, stores=stores)


def test_estimate_many_accepts_config_dicts_with_build():
    cfgs = STENCIL_CFGS[:3]
    specs = _specs(appspec.star3d, cfgs)
    via_specs = estimator.estimate_many(specs, V100)
    via_cfgs = estimator.estimate_many(
        [dict(c, grid=GRID) for c in cfgs], V100, build=appspec.star3d
    )
    _assert_bitwise_equal(via_specs, via_cfgs)
    with pytest.raises(TypeError, match="no build"):
        estimator.estimate_many([{"block": (32, 8, 4)}], V100)

"""Golden-file regression tests for the explore CLI.

Each golden file under ``tests/golden/`` is the exact ``--json`` summary of a
small, fully deterministic CLI sweep (seeded 24-config subsample of the
stencil25 space) on one machine model, with volatile fields (wall-clock,
store path) stripped.  Any change to machine constants, the estimator, the
capacity fits, the ranking order, or the CLI summary schema shows up as a
diff here — this is what pins "V100 results are bit-identical" across
refactors, and does the same for every other registered architecture.

Regenerating after an INTENDED model change::

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_golden_cli.py

then inspect and commit the rewritten files under ``tests/golden/``.
"""
from __future__ import annotations

import json
import os
from pathlib import Path

import pytest

from repro.explore import cli

pytestmark = pytest.mark.slow  # full-space CLI sweeps; excluded from the fast lane

GOLDEN_DIR = Path(__file__).parent / "golden"
REGEN = os.environ.get("REPRO_REGEN_GOLDEN") == "1"

CASES = {
    "explore_stencil25_v100.json": ["--machine", "v100"],
    "explore_stencil25_a100.json": ["--machine", "a100"],
}
BASE_ARGS = [
    "--kernel", "stencil25",
    "--sample", "24",
    "--seed", "7",
    "--top", "5",
    "--no-store",
    "--json",
]


def _volatile_stripped(summary: dict) -> dict:
    out = dict(summary)
    out.pop("wall_s", None)
    out.pop("store", None)
    return out


def _run_cli(extra: list[str], capsys) -> dict:
    rc = cli.main(BASE_ARGS + extra)
    captured = capsys.readouterr()
    assert rc == 0, captured.err
    return _volatile_stripped(json.loads(captured.out))


@pytest.mark.parametrize("golden_name", sorted(CASES))
def test_cli_sweep_matches_golden(golden_name, capsys):
    got = _run_cli(CASES[golden_name], capsys)
    path = GOLDEN_DIR / golden_name
    if REGEN:
        path.write_text(json.dumps(got, indent=2, sort_keys=True) + "\n")
        pytest.skip(f"regenerated {path}")
    assert path.exists(), (
        f"golden file {path} missing — generate it with "
        "REPRO_REGEN_GOLDEN=1 (see module docstring)"
    )
    want = json.loads(path.read_text())
    assert got == want, (
        f"CLI output diverged from {golden_name}; if the change is intended, "
        "regenerate with REPRO_REGEN_GOLDEN=1 and commit the diff"
    )


def test_goldens_disagree_across_machines():
    """The two golden files must differ in ranking/metrics — if they ever
    collapse to identical outputs, the machine parameter is not reaching the
    estimator."""
    v100 = json.loads((GOLDEN_DIR / "explore_stencil25_v100.json").read_text())
    a100 = json.loads((GOLDEN_DIR / "explore_stencil25_a100.json").read_text())
    assert v100["machine"] != a100["machine"]
    assert v100["top"] != a100["top"]

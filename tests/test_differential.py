"""Differential test layer: estimator vs deterministic LRU cache simulation,
for EVERY registered GPU architecture.

The paper validates the §III volume estimates against hardware performance
counters on one machine (V100); its follow-up (arXiv:2204.14242) repeats the
exercise on A100 by swapping machine constants.  Offline, the measurement
stand-in is ``core/exactcount.py`` — a sectored-LRU simulation fed the exact
address streams — which is independent of the estimator's compulsory/capacity
split, so agreement is a real cross-check, not a tautology.

For a seeded sample of stencil25 / LBM configurations we assert per-level
relative-error envelopes on every registered GPU machine:

* L2<-L1 load volume: tight (paper Figs 6/7: few-% stencil, ~10% LBM),
* DRAM store volume: tight (write-allocate + dirty flush is nearly exact),
* DRAM load volume: tight for the streaming-dominated stencil (Fig 14);
  loose for LBM, where the paper itself reports the largest deviations
  (Fig 16 — the capacity model overestimates pdf refetches vs true LRU).

The envelopes are regression pins: they encode today's model quality per
architecture so a future refactor cannot silently degrade one machine.
"""
from __future__ import annotations

import random

import pytest

from repro.core import appspec, estimator, exactcount
from repro.core.machine import gpu_machines

pytestmark = pytest.mark.slow  # LRU simulations; excluded from the fast lane

SEED = 20260729
N_PER_KERNEL = 2
# smaller-than-paper grids keep each LRU simulation at a few seconds while
# still providing >= 2 full waves on the widest machine (H100: 132 SMs,
# register-limited to 1 block/SM -> wave of 132 blocks; grids below launch
# 1024+ blocks)
GRIDS = {"stencil25": (128, 128, 64), "lbm_d3q15": (128, 128, 64)}
BUILDERS = {"stencil25": appspec.star3d, "lbm_d3q15": appspec.lbm_d3q15}
SPACES = {
    "stencil25": appspec.stencil_config_space,
    "lbm_d3q15": appspec.lbm_config_space,
}

# per-kernel, per-level max relative error |est - sim| / sim (see module doc)
ENVELOPE = {
    "stencil25": {"v_l2l1_load": 0.15, "v_dram_load": 0.10, "v_dram_store": 0.10},
    "lbm_d3q15": {"v_l2l1_load": 0.30, "v_dram_load": 1.00, "v_dram_store": 0.15},
}


def _sampled_configs(kernel: str) -> list[dict]:
    """Deterministic sample of warp-coalesced configurations.

    The paper validates its volume model on warp-contiguous layouts; sub-warp
    x-widths shatter sectors into the model's known worst case (they are also
    down-ranked by the L1 term long before the DRAM level matters), so the
    differential sample draws from bx >= 32 configs.
    """
    cfgs = [c for c in SPACES[kernel]() if c["block"][0] >= 32]
    return random.Random(SEED).sample(cfgs, N_PER_KERNEL)


def _rel(est: float, sim: float) -> float:
    return abs(est - sim) / max(sim, 1e-9)


# one LRU simulation costs seconds; both tests below share (machine, config)
# pairs, so memoize per session
_MEMO: dict = {}


def _est_and_sim(machine_key, kernel, cfg):
    key = (machine_key, kernel, cfg["block"], cfg["fold"])
    if key not in _MEMO:
        machine = gpu_machines()[machine_key]
        spec = BUILDERS[kernel](
            block=cfg["block"], fold=cfg["fold"], grid=GRIDS[kernel]
        )
        _MEMO[key] = (
            estimator.estimate(spec, machine, method="sym"),
            exactcount.simulate(spec, machine),
        )
    return _MEMO[key]


@pytest.mark.parametrize("machine_key", sorted(gpu_machines()))
@pytest.mark.parametrize("kernel", sorted(BUILDERS))
def test_estimator_matches_lru_simulation_within_envelope(machine_key, kernel):
    env = ENVELOPE[kernel]
    for cfg in _sampled_configs(kernel):
        est, sim = _est_and_sim(machine_key, kernel, cfg)
        for level, bound in env.items():
            e, s = getattr(est, level), getattr(sim, level)
            assert _rel(e, s) <= bound, (
                f"{kernel} {cfg['block']} on {machine_key}: {level} "
                f"est={e:.2f} sim={s:.2f} rel={_rel(e, s):.3f} > {bound}"
            )


@pytest.mark.parametrize("machine_key", sorted(gpu_machines()))
def test_dram_load_never_below_compulsory(machine_key):
    """Structural invariant on every architecture: the simulated DRAM load can
    never beat the compulsory (cold-footprint) volume the estimator derives —
    if it does, the wave/footprint geometry is wrong for that machine."""
    for cfg in _sampled_configs("stencil25")[:1]:
        est, sim = _est_and_sim(machine_key, "stencil25", cfg)
        assert sim.v_dram_load >= 0.95 * est.v_dram_load_comp

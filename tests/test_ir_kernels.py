"""The IR frontend's payoff: attention and wkv rank end-to-end through the GPU
analytic pipeline (estimate_many + Study + cross-machine + CLI), store keys are
canonical AccessIR fingerprints (spelling-invariant, collision-free), and large
stores load in parallel."""
from __future__ import annotations

import json

import pytest

from repro.core import estimator, model
from repro.core.machine import A100_40GB, V100
from repro.explore import Study
from repro.explore.registry import attention_gpu_space, get_kernel, wkv_gpu_space
from repro.explore.store import ResultStore
from repro.frontend import attention_gpu_ir, ir_fingerprint, lower_gpu, wkv_gpu_ir


def sweep(kernel, configs=None, machine=None, store=None):
    """Single-machine Study shorthand (the old ``engine.sweep`` surface)."""
    return Study(kernel, configs=configs, machine=machine, store=store).result()


def compare(kernel, machines, configs=None):
    """Multi-machine Study shorthand (the old ``crossmachine.compare``)."""
    return Study(kernel, configs=configs, machines=machines).compare()

# small problem instances keep each full estimate cheap
ATTN = dict(s=512, heads=8, d=16)
WKV = dict(BH=8, S=512, K=16)


# --------------------------------------------------------------------------- #
# registry + family resolution


def test_registry_families_and_backend_resolution():
    for family in ("stencil25", "lbm_d3q15", "attention", "wkv"):
        gpu = get_kernel(family, backend="gpu")
        tpu = get_kernel(family, backend="tpu")
        assert gpu.backend == "gpu" and gpu.build_ir is not None
        assert tpu.backend == "tpu" and tpu.tpu_configs is not None
        assert gpu.family == tpu.family == family
    # tpu-named entries resolve back to the gpu variant and vice versa
    assert get_kernel("attention_tpu", backend="gpu").name == "attention"
    assert get_kernel("wkv", backend="tpu").name == "wkv_tpu"
    with pytest.raises(KeyError, match="unknown kernel"):
        get_kernel("attention_gpu")


def test_gpu_spaces_enumerate():
    attn = attention_gpu_space().configs()
    assert len(attn) == 19
    assert all(c["block"][0] * c["block"][1] in (256, 512) for c in attn)
    wkv = wkv_gpu_space().configs()
    assert len(wkv) == 25
    assert all(
        c["block"][0] <= c["chunk"] and c["block"][1] <= c["chunk"] for c in wkv
    )


# --------------------------------------------------------------------------- #
# estimate_many: batched path stays bit-identical on the new kernels


@pytest.mark.parametrize(
    "build_ir,cfgs",
    [
        (
            attention_gpu_ir,
            [{"block": (16, 16, 1), **ATTN}, {"block": (64, 4, 1), **ATTN}],
        ),
        (
            wkv_gpu_ir,
            [
                {"block": (16, 16, 1), "chunk": 32, **WKV},
                {"block": (32, 8, 1), "chunk": 64, **WKV},
            ],
        ),
    ],
    ids=["attention", "wkv"],
)
def test_estimate_many_bitwise_on_ir_kernels(build_ir, cfgs):
    specs = [lower_gpu(build_ir(**c)) for c in cfgs]
    batched = estimator.estimate_many(specs, V100)
    for spec, got in zip(specs, batched):
        ref = estimator.estimate(spec, V100)
        assert got.v_dram_load == ref.v_dram_load
        assert got.v_dram_store == ref.v_dram_store
        assert got.v_l2l1_load == ref.v_l2l1_load
        assert got.l1_cycles == ref.l1_cycles
        assert (
            model.predict(spec, got, V100).glups
            == model.predict(spec, ref, V100).glups
        )


# --------------------------------------------------------------------------- #
# sweep + crossmachine + CLI end-to-end


def test_attention_sweeps_through_gpu_pipeline(tmp_path):
    cfgs = [{"block": b, **ATTN} for b in [(16, 16, 1), (64, 4, 1), (4, 64, 1)]]
    res = sweep("attention", configs=cfgs, machine="a100", store=tmp_path / "a.jsonl")
    assert res.backend == "gpu" and len(res.records) == 3
    assert all(r.metrics["glups"] > 0 for r in res.records)
    glups = [r.metrics["glups"] for r in res.records]
    assert glups == sorted(glups, reverse=True)  # best-first
    assert res.records[0].config in [r.config for r in res.pareto()]
    # resumable: every config is a cache hit on re-sweep
    again = sweep("attention", configs=cfgs, machine="a100", store=tmp_path / "a.jsonl")
    assert again.stats.cache_hits == 3 and again.stats.evaluated == 0


def test_wkv_chunk_ranking_through_gpu_pipeline():
    cfgs = [
        {"block": (16, 16, 1), "chunk": c, **WKV} for c in (16, 32, 64, 128)
    ]
    res = sweep("wkv", configs=cfgs, machine="v100")
    assert len(res.records) == 4
    # the chunk axis must reproduce the chunked-WKV tradeoff analytically:
    # per-token DRAM traffic shrinks monotonically with the chunk length
    # (r/k/v/w rows are reused across the L^2 intra-chunk pairs)
    by_chunk = {r.config["chunk"]: r.metrics["v_dram"] for r in res.records}
    dram = [by_chunk[c] for c in (16, 32, 64, 128)]
    assert dram == sorted(dram, reverse=True) and len(set(dram)) == 4


def test_crossmachine_attention_and_wkv():
    cfgs = [{"block": b, **ATTN} for b in [(16, 16, 1), (64, 4, 1)]]
    cm = compare("attention", ["v100", "a100"], configs=cfgs)
    assert cm.backend == "gpu" and set(cm.results) == {"V100", "A100"}
    assert all(w.placements[w.machine][0] == 0 for w in cm.winners)
    cfgs = [{"block": (16, 16, 1), "chunk": c, **WKV} for c in (16, 64)]
    cm = compare("wkv", ["v100", "a100", "h100"], configs=cfgs)
    assert set(cm.results) == {"V100", "A100", "H100"}


def test_cli_attention_gpu_and_backend_flag(capsys):
    from repro.explore import cli

    rc = cli.main(
        ["--kernel", "attention", "--machine", "a100", "--sample", "4",
         "--no-store", "--json"]
    )
    out = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert out["backend"] == "gpu" and out["kernel"] == "attention"
    assert out["candidates"] == 4 and len(out["top"]) == 4
    # --backend tpu resolves the family's Pallas entry
    rc = cli.main(
        ["--kernel", "attention", "--backend", "tpu", "--top", "2", "--no-store",
         "--json"]
    )
    out = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert out["backend"] == "tpu" and out["kernel"] == "attention_tpu"


def test_cli_wkv_gpu_smoke(capsys):
    from repro.explore import cli

    rc = cli.main(["--kernel", "wkv", "--sample", "4", "--no-store"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "chunk=" in out  # config formatting shows the chunk axis


# --------------------------------------------------------------------------- #
# store-key canonicalization (AccessIR fingerprint)


def test_store_key_canonicalizes_benign_spellings(tmp_path):
    """List-vs-tuple blocks and explicitly-spelled default arguments lower to
    the same AccessIR -> one store entry, hit on re-sweep."""
    p = tmp_path / "attn.jsonl"
    first = sweep(
        "attention",
        configs=[{"block": (16, 16, 1), **ATTN}],
        machine="a100",
        store=p,
    )
    assert first.stats.evaluated == 1
    respelled = sweep(
        "attention",
        configs=[
            {
                "block": [16, 16, 1],  # list spelling
                "s": ATTN["s"],
                "heads": ATTN["heads"],
                "d": ATTN["d"],
                "dtype_bits": 32,  # benign: explicitly the default
            }
        ],
        machine="a100",
        store=p,
    )
    assert respelled.stats.cache_hits == 1 and respelled.stats.evaluated == 0
    assert len(ResultStore(p)) == 1


def test_store_key_collision_regression(tmp_path):
    """Aliasing regression: distinct address streams can never share a key —
    block permutations, chunk changes and dtype changes all key apart."""
    variants = [
        {"block": (16, 16, 1), "chunk": 32, **WKV},
        {"block": (32, 8, 1), "chunk": 32, **WKV},  # permuted-ish block
        {"block": (16, 16, 1), "chunk": 64, **WKV},  # different chunk
        {"block": (16, 16, 1), "chunk": 32, **{**WKV, "K": 32}},  # different K
    ]
    fps = {ir_fingerprint(wkv_gpu_ir(**v)) for v in variants}
    assert len(fps) == len(variants)
    p = tmp_path / "wkv.jsonl"
    for v in variants:
        sweep("wkv", configs=[v], machine="v100", store=p)
    assert len(ResultStore(p)) == len(variants)
    # and each re-sweeps as a hit against its own entry
    for v in variants:
        r = sweep("wkv", configs=[v], machine="v100", store=p)
        assert r.stats.cache_hits == 1 and r.stats.evaluated == 0


# --------------------------------------------------------------------------- #
# parallel warm path


def test_store_load_modes_agree(tmp_path):
    """Lazy key-scan (default), eager serial (0) and eager pool (N) loads all
    expose identical contents, including last-write-wins and corrupt-tail
    skipping."""
    p = tmp_path / "big.jsonl"
    w = ResultStore(p, load_workers=0)
    for i in range(500):
        w.put(f"k{i}", {"v": i, "blob": [i] * 8}, machine="V100")
    w.put("k0", {"v": -1, "blob": []}, machine="A100")  # supersede
    with p.open("a") as f:
        f.write('{"key": "trunc')  # killed mid-write
    lazy = ResultStore(p)  # default: lazy key-scan
    serial = ResultStore(p, load_workers=0)
    pooled = ResultStore(p, load_workers=4)
    for s in (lazy, serial, pooled):
        assert len(s) == 500
        assert s.get("k0") == {"v": -1, "blob": []}
        assert s.get("nope") is None
    assert lazy.machines() == serial.machines() == pooled.machines()
    assert {k: lazy.get(k) for k in lazy.keys()} == {
        k: serial.get(k) for k in serial.keys()
    }


def test_store_lazy_load_recovers_superseded_record_behind_corrupt_line(tmp_path):
    """A torn write that still scans a complete key (ends on '}') must not
    shadow an earlier valid record for that key: the lazy path falls back to
    an eager reload and serves exactly what load_workers=0 would."""
    p = tmp_path / "torn.jsonl"
    w = ResultStore(p, load_workers=0)
    w.put("K", {"v": 1}, machine="V100")
    w.put("other", {"v": 2}, machine="V100")
    with p.open("a") as f:
        f.write('{"key": "K", "payload": {"v"}\n')  # torn, but scannable key
    eager = ResultStore(p, load_workers=0)
    lazy = ResultStore(p)
    assert lazy.get("K") == eager.get("K") == {"v": 1}
    assert lazy.get("other") == {"v": 2}
    assert len(lazy) == len(eager) == 2
    assert lazy.machines() == eager.machines()


def test_store_lazy_load_survives_multiple_scannable_corrupt_lines(tmp_path):
    """Two or more torn-but-key-scannable lines: the first materialization
    failure triggers the eager reload (dropping them all); later touches of
    the other dropped keys must return None, and machines()/compact() must not
    crash."""
    p = tmp_path / "torn2.jsonl"
    w = ResultStore(p, load_workers=0)
    w.put("good", {"v": 1}, machine="V100")
    with p.open("a") as f:
        f.write('{"key": "k1", "payload": {"v"}\n')
        f.write('{"key": "k2", "payload": {"v"}\n')
    lazy = ResultStore(p)
    assert lazy.machines() == {"V100": 1}  # reloads; must not KeyError
    assert lazy.get("k1") is None and lazy.get("k2") is None
    assert lazy.get("good") == {"v": 1} and len(lazy) == 1
    lazy2 = ResultStore(p)
    lazy2.compact()
    assert ResultStore(p, load_workers=0).machines() == {"V100": 1}


def test_store_lazy_load_parses_only_touched_payloads(tmp_path):
    """The lazy path's contract: loading is a key scan; a payload deserializes
    on its first hit (and superseded duplicates never deserialize at all)."""
    p = tmp_path / "lazy.jsonl"
    w = ResultStore(p, load_workers=0)
    for i in range(20):
        w.put(f"k{i}", {"v": i}, machine="V100")
    s = ResultStore(p)
    untouched = [v for v in s._mem.values() if isinstance(v, str)]
    assert len(untouched) == 20  # nothing parsed yet
    assert s.get("k3") == {"v": 3}
    assert isinstance(s._mem["k3"], dict)  # materialized in place
    assert sum(isinstance(v, str) for v in s._mem.values()) == 19
    # compact() materializes everything and rewrites a loadable file
    s.compact()
    assert ResultStore(p).get("k19") == {"v": 19}

"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention import flash_attention, mha_ref
from repro.kernels.lbm_d3q15 import init_fields, lbm_step, lbm_step_ref
from repro.kernels.stencil25 import select_block, stencil25, stencil25_ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=4e-2, atol=4e-2) if dtype == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("shape", [(16, 16, 32), (32, 16, 48), (24, 32, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("block", [(8, 8), (8, 16)])
def test_stencil25_allclose(shape, dtype, block):
    r = 4
    if shape[0] % block[0] or shape[1] % block[1]:
        pytest.skip("block does not tile grid")
    src = jnp.asarray(RNG.normal(size=shape), dtype)
    out = stencil25(src, r=r, block=block, interpret=True)
    ref = stencil25_ref(src, r=r)
    sl = (slice(r, -r),) * 3
    np.testing.assert_allclose(
        np.asarray(out[sl], np.float32), np.asarray(ref[sl], np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("r", [1, 2, 4])
def test_stencil_ranges(r):
    src = jnp.asarray(RNG.normal(size=(16, 16, 24)), jnp.float32)
    out = stencil25(src, r=r, block=(8, 8), interpret=True)
    ref = stencil25_ref(src, r=r)
    sl = (slice(r, -r),) * 3
    np.testing.assert_allclose(out[sl], ref[sl], rtol=3e-5, atol=3e-5)


def test_stencil_estimator_selection_valid():
    blk, est = select_block((64, 64, 128), r=4)
    assert est.feasible
    assert est.vmem_bytes < 100 * 2**20
    src = jnp.asarray(RNG.normal(size=(64, 64, 128)), jnp.float32)
    out = stencil25(src, r=4, block=blk, interpret=True)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("shape", [(16, 16, 32), (16, 32, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("block", [(8, 8), (4, 16)])
def test_lbm_allclose(shape, dtype, block):
    f, phase, vel = init_fields(shape, dtype=dtype)
    fo, po = lbm_step(f, phase, vel, block=block, interpret=True)
    fr, pr = lbm_step_ref(f, phase, vel)
    s = (slice(None), slice(1, -1), slice(1, -1), slice(None))
    np.testing.assert_allclose(fo[s], fr[s], **_tol(dtype))
    np.testing.assert_allclose(po[1:-1, 1:-1], pr[1:-1, 1:-1], **_tol(dtype))


def test_lbm_mass_conservation():
    """Collision conserves phi (sum over q of f_eq == phi); streaming only moves
    mass: interior sum drift must be tiny for zero velocity."""
    f, phase, vel = init_fields((16, 16, 32))
    fr, pr = lbm_step_ref(f, phase, 0.0 * vel)
    assert abs(float(pr.sum()) - float(phase.sum())) / float(phase.sum()) < 1e-3


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_allclose(dtype, hq, hkv, causal):
    B, S, D = 2, 256, 64
    q = jnp.asarray(RNG.normal(size=(B, hq, S, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, hkv, S, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, hkv, S, D)), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=128, block_kv=64, interpret=True)
    ref = mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("bq,bkv", [(64, 64), (128, 256), (256, 128)])
def test_flash_attention_block_invariance(bq, bkv):
    """Output must be block-size invariant (online softmax correctness)."""
    B, H, S, D = 1, 2, 256, 32
    q = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, block_q=bq, block_kv=bkv, interpret=True)
    b = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("K", [16, 32])
def test_wkv_pallas_allclose(chunk, K):
    from repro.kernels.wkv import wkv, wkv_ref

    BH, S = 3, 128
    r, k, v = (
        jnp.asarray(RNG.normal(size=(BH, S, K)).astype(np.float32)) for _ in range(3)
    )
    wlog = -jnp.exp(
        jnp.asarray(RNG.normal(size=(BH, S, K)).astype(np.float32)).clip(-8, 4)
    )
    u = jnp.asarray(RNG.normal(size=(K,)).astype(np.float32))
    ref, _ = wkv_ref(r, k, v, wlog, u)
    out = wkv(r, k, v, wlog, u, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=5e-4, atol=5e-4)


def test_wkv_estimator_matches_dryrun_finding():
    """The analytic estimator must pick the chunk the dry-run hillclimb found
    empirically (L=64 for the rwkv6 production shape) — the paper's core thesis."""
    from repro.kernels.wkv import select_chunk

    L, est = select_chunk(BH=64, S=4096, K=64)
    assert L == 64
    assert est.feasible

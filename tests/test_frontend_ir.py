"""AccessIR data model, canonical fingerprint, Pallas tracing + non-affine guard,
and the symset fast paths the IR-opened kernels exercise (zero-stride and
offset-covered strided x accesses)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import tpu_estimator as te
from repro.core.address import Access, Field, ThreadBox
from repro.core.machine import TPU_V5E
from repro.frontend import (
    AccessIR,
    IRAccess,
    IRField,
    NonAffineIndexMapError,
    dedupe_ir,
    fold_ir,
    ir_fingerprint,
    lower_gpu,
    lower_tpu,
    trace_index_map,
    trace_pallas,
)

# --------------------------------------------------------------------------- #
# data model


def _element_ir(**kw):
    defaults = dict(
        name="k",
        fields=(IRField("a", (64, 8, 8), 64),),
        accesses=(IRAccess("a", (1, 64, 512), 0),),
        iter_shape=(64, 8, 8),
        block=(32, 4, 2),
    )
    defaults.update(kw)
    return AccessIR(**defaults)


def test_ir_validation_errors():
    with pytest.raises(ValueError, match="unknown field"):
        _element_ir(accesses=(IRAccess("nope", (1, 64, 512), 0),))
    with pytest.raises(ValueError, match="duplicate field"):
        _element_ir(fields=(IRField("a", (8,)), IRField("a", (8,))))
    with pytest.raises(ValueError, match="iteration dims"):
        _element_ir(accesses=(IRAccess("a", (1, 64), 0),))
    with pytest.raises(ValueError, match="block rank|iteration rank"):
        _element_ir(block=(32, 4))
    with pytest.raises(ValueError, match="tile rank"):
        IRAccess("a", ((1, 2), (3, 4)), (0, 0), tile=(8,))
    with pytest.raises(ValueError, match="single element index"):
        IRAccess("a", ((1, 2), (3, 4)), (0, 0))
    with pytest.raises(ValueError, match="mixed"):
        AccessIR(
            name="m",
            fields=(IRField("a", (8, 8)), IRField("b", (8, 8))),
            accesses=(
                IRAccess("a", (1, 8), 0),
                IRAccess("b", ((1, 0), (0, 1)), (0, 0), tile=(8, 8)),
            ),
            iter_shape=(8, 8),
        )


def test_ir_spelling_normalisation():
    """Lists and tuples, flat and nested coefficient spellings: one identity."""
    a = IRAccess("a", [1, 64, 512], 3)
    b = IRAccess("a", ((1, 64, 512),), (3,))
    assert a == b
    ir_a = _element_ir(accesses=(a,), block=[32, 4, 2], iter_shape=[64, 8, 8])
    ir_b = _element_ir(accesses=(b,))
    assert ir_a == ir_b
    assert ir_fingerprint(ir_a) == ir_fingerprint(ir_b)


def test_fingerprint_ignores_meta_and_access_order_only():
    base = _element_ir(
        accesses=(IRAccess("a", (1, 64, 512), 0), IRAccess("a", (1, 64, 512), 4))
    )
    permuted = _element_ir(
        accesses=(IRAccess("a", (1, 64, 512), 4), IRAccess("a", (1, 64, 512), 0))
    )
    with_meta = _element_ir(
        accesses=base.accesses, meta={"display": "only", "benign": 1}
    )
    assert ir_fingerprint(base) == ir_fingerprint(permuted) == ir_fingerprint(with_meta)
    # every semantic field keys apart
    assert ir_fingerprint(base) != ir_fingerprint(_element_ir(block=(16, 8, 2)))
    assert ir_fingerprint(base) != ir_fingerprint(_element_ir(iter_shape=(32, 8, 8), block=(32, 4, 2)))
    assert ir_fingerprint(base) != ir_fingerprint(
        _element_ir(accesses=(IRAccess("a", (1, 64, 512), 1),))
    )
    assert ir_fingerprint(base) != ir_fingerprint(
        _element_ir(fields=(IRField("a", (64, 8, 8), 32),))
    )
    assert ir_fingerprint(base) != ir_fingerprint(_element_ir(regs_per_thread=128))


def test_fold_and_dedupe_match_address_layer():
    from repro.core.address import dedupe_accesses, fold_accesses

    f = Field("a", (64, 8, 8), 8)
    legacy = dedupe_accesses(
        fold_accesses(
            [Access(f, (1, 64, 512), 0), Access(f, (1, 64, 512), 1)], (1, 2, 2)
        )
    )
    ir_acc = dedupe_ir(
        fold_ir(
            [IRAccess("a", (1, 64, 512), 0), IRAccess("a", (1, 64, 512), 1)],
            (1, 2, 2),
        )
    )
    assert [(a.coeffs, a.offset, a.is_store) for a in legacy] == [
        (ia.coeffs[0], ia.offset[0], ia.is_store) for ia in ir_acc
    ]


# --------------------------------------------------------------------------- #
# Pallas tracing


def test_trace_index_map_recovers_affine_forms():
    m, o = trace_index_map(lambda i, j, k: (i + 2 * k, 3, j - 1), (4, 5, 6))
    assert m == ((1, 0, 2), (0, 0, 0), (0, 1, 0))
    assert o == (0, 3, -1)
    # extent-1 dims contribute zero coefficients
    m, o = trace_index_map(lambda i, j: (i + j,), (7, 1))
    assert m == ((1, 0),) and o == (0,)
    # empty grid: constant map
    m, o = trace_index_map(lambda: (2, 3), ())
    assert m == ((), ()) and o == (2, 3)


@pytest.mark.parametrize(
    "bad,grid",
    [
        (lambda i: (min(i, 3),), (8,)),  # clamped boundary
        (lambda i: (max(i - 1, 0),), (8,)),  # clamped at origin-side
        (lambda i, j: (i * j,), (4, 4)),  # cross term
        (lambda i: (i * i,), (5,)),  # curvature
    ],
)
def test_trace_index_map_rejects_non_affine(bad, grid):
    with pytest.raises(NonAffineIndexMapError, match="not affine"):
        trace_index_map(bad, grid)


def test_trace_index_map_accepts_domain_affine_clamp():
    """min(i, 3) over grid (4,) IS affine on its domain (i <= 3): accepted."""
    m, o = trace_index_map(lambda i: (min(i, 3),), (4,))
    assert m == ((1,),) and o == (0,)


def test_estimate_raises_on_non_affine_index_map():
    cfg = te.PallasConfig(
        name="clamped",
        grid=(8,),
        accesses=(
            te.BlockAccess("x", (8, 128), lambda i: (min(i + 1, 6), 0), 32),
        ),
        flops_per_step=0.0,
    )
    with pytest.raises(NonAffineIndexMapError, match="clamped.x"):
        te.estimate(cfg, TPU_V5E)


def test_sweep_raises_on_non_affine_index_map(tmp_path):
    """The store path must refuse (not silently alias) a non-affine map that
    agrees with an affine one at the origin/unit-step probes."""
    from repro.explore import Study

    cfg = te.PallasConfig(
        name="clamped",
        grid=(8,),
        accesses=(
            te.BlockAccess("x", (8, 128), lambda i: (min(i, 3), 0), 32),
        ),
        flops_per_step=0.0,
    )
    with pytest.raises(NonAffineIndexMapError):
        Study("stencil25_tpu", configs=[cfg], store=tmp_path / "s.jsonl").result()


def test_trace_pallas_roundtrips_with_lower_tpu():
    cfg = te.PallasConfig(
        name="mm",
        grid=(4, 3, 2),
        accesses=(
            te.BlockAccess("A", (128, 64), lambda i, j, k: (i, k), 16),
            te.BlockAccess("B", (64, 128), lambda i, j, k: (k, j), 16),
            te.BlockAccess("O", (128, 128), lambda i, j, k: (i, j), 16, True),
        ),
        flops_per_step=7.0,
        is_matmul=True,
        scratch_bytes=256,
        meta={"bm": 128},
    )
    ir = trace_pallas(cfg)
    assert ir.granularity == "block"
    assert ir.iter_shape == (4, 3, 2) and ir.scratch_bytes == 256
    assert trace_pallas(lower_tpu(ir)) == ir
    # the traced IR estimates identically to the closure-based config
    e_cfg = te.estimate(cfg)
    e_ir = te.estimate_ir(ir)
    assert e_cfg == e_ir


def test_estimate_ir_rejects_element_granular_ir():
    ir = _element_ir()
    with pytest.raises(ValueError, match="element-granular"):
        te.estimate_ir(ir)
    with pytest.raises(ValueError, match="block-granular"):
        lower_gpu(
            trace_pallas(
                te.PallasConfig(
                    "c", (2,), (te.BlockAccess("x", (8, 128), lambda i: (i, 0), 32),), 0.0
                )
            )
        )


def test_trace_pallas_rejects_duplicate_operands_and_rank_mismatch():
    dup = te.PallasConfig(
        "d", (2,),
        (
            te.BlockAccess("x", (8, 128), lambda i: (i, 0), 32),
            te.BlockAccess("x", (8, 128), lambda i: (i, 0), 32),
        ),
        0.0,
    )
    with pytest.raises(ValueError, match="duplicate operand"):
        trace_pallas(dup)
    mismatch = te.PallasConfig(
        "m", (2,), (te.BlockAccess("x", (8, 128), lambda i: (i,), 32),), 0.0
    )
    with pytest.raises(ValueError, match="rank"):
        trace_pallas(mismatch)


# --------------------------------------------------------------------------- #
# symset fast paths (zero-stride x, offset-covered strided x): exactness vs
# both the reference per-access path and the enumeration method


def _sets_bytes(sets, granularity):
    return sum(s.cardinality for s in sets.values()) * granularity


@pytest.mark.parametrize("granularity", [32, 128])
@pytest.mark.parametrize(
    "cx,offsets",
    [
        (0, list(range(16))),  # x-invariant row (attention q / wkv r)
        (16, list(range(16))),  # stride fully covered by offsets (k/v panels)
        (16, [0, 1, 2, 3]),  # stride NOT covered: sparse enumeration
        (-16, list(range(16))),  # negative stride, covered
        (5, [0, 1, 2]),  # odd stride, partial cover
    ],
)
def test_grouped_strided_paths_match_enum(cx, offsets, granularity):
    from repro.core import footprint as fe
    from repro.core import symset as fs

    f = Field("A", (64, 8, 4), 4, alignment=32)
    accesses = [Access(f, (cx, 64, 512), o) for o in offsets]
    box = ThreadBox(x=(1, 9), y=(0, 5), z=(1, 3))
    enum_sets = fe.line_sets(accesses, [box], granularity)
    ref_sets = fs.field_interval_sets(accesses, [box], granularity)
    grouped = fs.field_interval_sets_grouped(
        fs.group_accesses(accesses), [box], granularity
    )
    want = sum(len(s) for s in enum_sets.values()) * granularity
    assert _sets_bytes(ref_sets, granularity) == want
    assert _sets_bytes(grouped, granularity) == want
    # canonical representation identical between ref and grouped paths
    for name in ref_sets:
        assert np.array_equal(ref_sets[name].starts, grouped[name].starts)
        assert np.array_equal(ref_sets[name].ends, grouped[name].ends)

"""The backend-agnostic Study facade + unified estimator protocol.

Covers the API-redesign contracts:

* ``Study`` is THE entry point (the legacy ``sweep``/``compare`` shims are
  gone) and is deterministic: two identical studies produce bit-identical
  results;
* multi-machine ``Study.run()`` evaluates the machine-independent per-config
  work ONCE (IR tracing counted via a wrapped builder, footprints via the
  shared ``EstimateCache`` hit counters) and is bit-identical to N independent
  single-machine sweeps;
* the v4 store payload round-trips every ``SweepRecord`` field on both
  backends, and keys carry the ``BUILDER_VERSION`` token;
* predicted-score ties sort deterministically by config fingerprint;
* unknown Pareto objectives fail loudly with a did-you-mean error.
"""
from __future__ import annotations

import json

import pytest

from repro.core import appspec
from repro.core.machine import A100_40GB, TPU_V5E, TPU_V6E, V100
from repro.core.record import record_from_payload, record_payload
from repro.explore import Study
from repro.explore.study import SweepRecord, sort_records
from repro.frontend import ir as ir_mod

GRID = (128, 64, 64)  # reduced grid keeps each full estimate cheap

CFGS = [
    {"block": (32, 8, 4), "fold": (1, 1, 1)},
    {"block": (16, 8, 8), "fold": (1, 1, 1)},
    {"block": (128, 1, 8), "fold": (1, 2, 1)},
    {"block": (4, 16, 16), "fold": (1, 1, 2)},
]


def build_small(block, fold=(1, 1, 1)):
    return appspec.star3d(block=block, fold=fold, grid=GRID)


def _tpu_cfgs():
    """Small Pallas candidates: two feasible, one far beyond the VMEM gate."""
    from repro.core import tpu_estimator as te

    def cfg(name, bz):
        return te.PallasConfig(
            name=name,
            grid=(256 // bz,),
            accesses=(
                te.BlockAccess(
                    name="x",
                    block_shape=(bz, 512, 128),
                    index_map=lambda i: (i, 0, 0),
                    dtype_bits=32,
                ),
            ),
            flops_per_step=1.0,
            is_matmul=False,
            meta={"bz": bz},
        )

    return [cfg("small", 8), cfg("mid", 16), cfg("huge", 256)]


# --------------------------------------------------------------------------- #
# facade determinism (the old sweep/compare shims are gone — same surface,
# one entry point)


def test_legacy_shims_are_gone():
    import repro.explore as explore

    assert not hasattr(explore, "sweep") and not hasattr(explore, "compare")
    with pytest.raises(ModuleNotFoundError):
        import repro.explore.engine  # noqa: F401
    with pytest.raises(ModuleNotFoundError):
        import repro.explore.crossmachine  # noqa: F401


def test_study_single_machine_is_deterministic():
    res = Study(build_small, configs=CFGS, machine=V100).result()
    again = Study(build_small, configs=CFGS, machine=V100).result()
    assert [r.config for r in res.records] == [r.config for r in again.records]
    assert [r.metrics for r in res.records] == [r.metrics for r in again.records]
    assert res.backend == "gpu" and res.machine == V100.name


def test_study_compare_is_deterministic():
    study = Study("stencil25", configs=CFGS, machines=["v100", "a100"])
    cm_new = study.compare()
    cm_old = Study("stencil25", configs=CFGS, machines=["v100", "a100"]).compare()
    assert cm_new.machines == cm_old.machines == ["V100", "A100"]
    assert cm_new.tau == cm_old.tau
    assert [w.placements for w in cm_new.winners] == [
        w.placements for w in cm_old.winners
    ]


def test_study_lazy_run_and_result_selection():
    study = Study(build_small, configs=CFGS, machines=[V100, A100_40GB])
    # .top() without an explicit .run() lazily executes, but needs a machine
    with pytest.raises(ValueError, match="spans machines"):
        study.top(2)
    top = study.top(2, machine="v100")  # canonicalized lookup
    assert len(top) == 2
    with pytest.raises(KeyError, match="not part of this study"):
        study.result("h100")
    with pytest.raises(ValueError, match="at least two"):
        Study(build_small, configs=CFGS, machine=V100).compare()


# --------------------------------------------------------------------------- #
# multi-machine fan-out: shared machine-independent work, bit-identical output


def test_multi_machine_study_matches_independent_sweeps():
    study = Study(build_small, configs=CFGS, machines=[V100, A100_40GB])
    multi = study.run()
    for machine in (V100, A100_40GB):
        solo = Study(build_small, configs=CFGS, machine=machine).result()
        got = multi.result(machine.name)
        assert [r.config for r in got.records] == [r.config for r in solo.records]
        # bit-for-bit: every metric, volume and prediction coincides
        assert [r.metrics for r in got.records] == [r.metrics for r in solo.records]
        assert [r.volumes for r in got.records] == [r.volumes for r in solo.records]
        assert [r.ranked.glups for r in got.records] == [
            r.ranked.glups for r in solo.records
        ]


def test_multi_machine_study_builds_each_config_once():
    """The ROADMAP item: N machines must NOT mean N enumerations/builds — the
    per-config IR is traced once and the machine-independent footprint work is
    served from the shared EstimateCache on every machine after the first."""
    calls = []

    def counting_build(block, fold=(1, 1, 1)):
        calls.append((tuple(block), tuple(fold)))
        return build_small(block, fold)

    study = Study(counting_build, configs=CFGS, machines=[V100, A100_40GB])
    study.run()
    assert len(calls) == len(CFGS)  # once per config, NOT per machine
    # the second machine's L1-stage work (bank-conflict cycles, warp requests,
    # block footprints) must be cache hits, not recomputes
    assert study.cache.hits >= len(CFGS)


def test_multi_machine_tpu_study_and_compare_shape():
    study = Study("wkv_tpu", configs=_tpu_cfgs(), machines=["tpuv5e", "tpuv6e"])
    cm = study.compare()
    assert cm.backend == "tpu" and cm.score_metric == "time_s"
    assert cm.machines == ["TPUv5e", "TPUv6e"]
    assert set(cm.tau) == {("TPUv5e", "TPUv6e")}
    assert all(w.placements[w.machine][0] == 0 for w in cm.winners)
    # the infeasible candidate is reported but never recommended, per machine
    for label in cm.machines:
        res = cm.results[label]
        assert len(res.records) == 3
        assert {r.config["name"] for r in res.top(5)} == {"small", "mid"}


def test_study_rejects_mixed_and_duplicate_machines():
    with pytest.raises(ValueError, match="needs a GPUMachine"):
        Study(build_small, configs=CFGS, machines=[V100, TPU_V5E])
    with pytest.raises(ValueError, match="duplicate"):
        Study(build_small, configs=CFGS, machines=["v100", "V100"])
    with pytest.raises(ValueError, match="not both"):
        Study(build_small, configs=CFGS, machine=V100, machines=[V100])


# --------------------------------------------------------------------------- #
# v4 store schema: unified payload round-trip + builder-version token


def _roundtrip(rec):
    blob = json.dumps(record_payload(rec), default=list)
    return record_from_payload(json.loads(blob), fingerprint=rec.fingerprint)


def test_v4_payload_roundtrips_gpu_records():
    for rec in Study(build_small, configs=CFGS, machine=V100).result().records:
        back = _roundtrip(rec)
        assert back.config == rec.config
        assert back.metrics == rec.metrics  # exact float round-trip via repr
        assert back.volumes == rec.volumes
        assert (back.time_s, back.limiter, back.feasible, back.backend) == (
            rec.time_s,
            rec.limiter,
            rec.feasible,
            rec.backend,
        )
        assert back.ranked.estimate == rec.ranked.estimate
        assert back.ranked.prediction == rec.ranked.prediction


def test_v4_payload_roundtrips_tpu_records_including_infeasible():
    res = Study("wkv_tpu", configs=_tpu_cfgs(), machine=TPU_V6E).result()
    assert any(not r.feasible for r in res.records)  # the huge candidate
    for rec in res.records:
        back = _roundtrip(rec)
        assert back.config == rec.config
        assert back.metrics == rec.metrics
        assert back.volumes == rec.volumes
        assert back.time_s == rec.time_s  # inf survives JSON
        assert back.feasible == rec.feasible and back.ranked is None


def test_store_records_carry_builder_version(tmp_path):
    from repro.explore.store import ResultStore

    p = tmp_path / "s.jsonl"
    Study(build_small, configs=CFGS[:2], machine=V100, store=p).run()
    lines = [json.loads(x) for x in p.read_text().splitlines()]
    assert all(rec["builder_version"] == ir_mod.BUILDER_VERSION for rec in lines)
    assert ResultStore(p).builder_versions() == {ir_mod.BUILDER_VERSION: 2}


def test_builder_version_bump_invalidates_keys(tmp_path, monkeypatch):
    """The alias-layer prerequisite: estimates recorded under one builder
    version must never be served under another — the token is part of the key
    derivation, so a bump misses instead of aliasing."""
    p = tmp_path / "s.jsonl"
    Study(build_small, configs=CFGS[:1], machine=V100, store=p).run()
    hit = Study(build_small, configs=CFGS[:1], machine=V100, store=p).result()
    assert hit.stats.cache_hits == 1 and hit.stats.evaluated == 0
    monkeypatch.setattr(ir_mod, "BUILDER_VERSION", ir_mod.BUILDER_VERSION + 1)
    miss = Study(build_small, configs=CFGS[:1], machine=V100, store=p).result()
    assert miss.stats.cache_hits == 0 and miss.stats.evaluated == 1


def test_stores_keys_accept_any_machine_spelling(tmp_path):
    """stores= keys canonicalize like machines= entries do — a lowercase key
    must not silently drop the store (losing all persistence)."""
    stores = {"v100": tmp_path / "v.jsonl", "A100-SXM4-40GB": tmp_path / "a.jsonl"}
    res = Study(
        build_small, configs=CFGS[:1], machines=["v100", "a100"], stores=stores
    ).run()
    for label in res.machines:
        assert res.results[label].store_path is not None
    assert (tmp_path / "v.jsonl").exists() and (tmp_path / "a.jsonl").exists()


def test_compare_fails_fast_on_single_machine_study(tmp_path):
    """compare() on a one-machine study must raise BEFORE estimating anything
    (the machine count is known at construction)."""
    study = Study(build_small, configs=CFGS, machine=V100, store=tmp_path / "s.jsonl")
    with pytest.raises(ValueError, match="at least two"):
        study.compare()
    assert not (tmp_path / "s.jsonl").exists()  # nothing ran, nothing persisted


def test_study_resume_is_incremental(tmp_path):
    p = tmp_path / "s.jsonl"
    first = Study(build_small, configs=CFGS[:2], machine=V100, store=p)
    assert first.result().stats.evaluated == 2
    # a later study over a superset pays only for what is missing
    second = Study(build_small, configs=CFGS, machine=V100, store=str(p))
    res = second.result()
    assert res.stats.cache_hits == 2 and res.stats.evaluated == 2
    # .resume() reloads from disk and re-runs: everything is now a hit
    resumed = second.resume().result()
    assert resumed.stats.cache_hits == 4 and resumed.stats.evaluated == 0
    assert [r.config for r in resumed.records] == [r.config for r in res.records]
    assert [r.metrics for r in resumed.records] == [r.metrics for r in res.records]


# --------------------------------------------------------------------------- #
# deterministic tie ordering


def _tied_record(fp: str, glups: float, backend: str = "gpu") -> SweepRecord:
    return SweepRecord(
        config={"fp": fp},
        backend=backend,
        time_s=1.0 / glups,
        limiter="DRAM",
        feasible=True,
        volumes={},
        metrics={"glups": glups, "time_s": 1.0 / glups},
        fingerprint=fp,
    )


def test_score_ties_break_on_fingerprint_not_input_order():
    a, b, c = _tied_record("aaa", 10.0), _tied_record("bbb", 10.0), _tied_record("ccc", 12.0)
    for order in ([a, b, c], [b, a, c], [c, b, a]):
        recs = list(order)
        sort_records(recs, "gpu")
        # best score first; the 10.0 tie always resolves the same way
        assert [r.fingerprint for r in recs] == ["ccc", "bbb", "aaa"]
    t1, t2 = _tied_record("xxx", 5.0, "tpu"), _tied_record("yyy", 5.0, "tpu")
    for order in ([t1, t2], [t2, t1]):
        recs = list(order)
        sort_records(recs, "tpu")
        assert [r.fingerprint for r in recs] == ["yyy", "xxx"]


# --------------------------------------------------------------------------- #
# pareto objective validation


def test_pareto_rejects_unknown_objectives_with_suggestion():
    res = Study(build_small, configs=CFGS, machine=V100).result()
    with pytest.raises(ValueError, match="did you mean 'glups'"):
        res.pareto(objectives=(("glup", "max"),))
    with pytest.raises(ValueError, match="'max' or 'min'"):
        res.pareto(objectives=(("glups", "maximize"),))
    with pytest.raises(ValueError, match="not a \\(metric"):
        res.pareto(objectives=("glups",))
    # valid custom objectives still work
    front = res.pareto(objectives=(("glups", "max"), ("v_dram", "min")))
    assert res.records[0].config in [r.config for r in front]


def test_pareto_rejects_gpu_objectives_on_tpu_records():
    res = Study("wkv_tpu", configs=_tpu_cfgs(), machine=TPU_V5E).result()
    with pytest.raises(ValueError, match="unknown objective metric"):
        res.pareto(objectives=(("glups", "max"),))
    assert {r.config["name"] for r in res.pareto()} <= {"small", "mid"}

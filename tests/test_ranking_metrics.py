"""Edge cases for the rank-correlation metrics in core/ranking.py
(paper §IV.H uses Kendall's tau to score estimator-vs-measured orderings)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.ranking import kendall_tau, spearman_rho


def test_perfect_agreement():
    a = [1.0, 2.0, 3.0, 4.0]
    assert kendall_tau(a, a) == 1.0
    assert spearman_rho(a, a) == pytest.approx(1.0)


def test_reversed_order():
    a = [1.0, 2.0, 3.0, 4.0]
    b = [4.0, 3.0, 2.0, 1.0]
    assert kendall_tau(a, b) == -1.0
    assert spearman_rho(a, b) == pytest.approx(-1.0)


def test_short_inputs_are_defined():
    # fewer than two elements: correlation is vacuous, defined as 1.0
    assert kendall_tau([], []) == 1.0
    assert kendall_tau([3.0], [7.0]) == 1.0
    assert spearman_rho([], []) == 1.0
    assert spearman_rho([3.0], [7.0]) == 1.0


def test_all_ties_degenerate():
    # constant sequences: no discordant or concordant pairs -> tau = 1.0,
    # zero rank variance -> rho = 1.0 (degenerate-denominator convention)
    a = [2.0, 2.0, 2.0]
    assert kendall_tau(a, a) == 1.0
    assert spearman_rho(a, a) == 1.0


def test_partial_ties_drop_from_tau_denominator():
    # tied pairs contribute neither concordant nor discordant
    a = [1.0, 1.0, 2.0]
    b = [1.0, 2.0, 3.0]
    # pairs: (0,1) tied in a; (0,2) and (1,2) concordant -> tau = 1
    assert kendall_tau(a, b) == 1.0
    b_rev = [3.0, 2.0, 1.0]
    assert kendall_tau(a, b_rev) == -1.0


def test_mismatched_lengths_rejected():
    with pytest.raises(AssertionError):
        kendall_tau([1.0, 2.0], [1.0])


def test_known_value():
    # classic example: one discordant pair among six
    a = [1, 2, 3, 4]
    b = [1, 2, 4, 3]
    assert kendall_tau(a, b) == pytest.approx((5 - 1) / 6)
    rho = spearman_rho(a, b)
    assert 0.7 < rho < 1.0


def test_invariance_under_monotone_transform():
    rng = np.random.default_rng(0)
    a = rng.normal(size=20)
    b = a + 0.01 * rng.normal(size=20)
    assert kendall_tau(a, np.exp(a)) == 1.0
    assert spearman_rho(a, a**3) == pytest.approx(1.0)
    assert kendall_tau(a, b) == kendall_tau(np.exp(a), b)

"""Golden-file regression tests for the lint CLI.

Two fixed points of the static analyzer, pinned as exact text output:

* a **clean** registry kernel (canonical stencil25 config on V100) — its
  report may carry warns/infos but zero errors, and the exact findings,
  witnesses and suggestions must not drift;
* a **seeded-bug fixture** (``racy_store``) — the write-write race must keep
  firing with the same witness points.

Regenerating after an INTENDED analyzer change::

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_golden_lint.py

then inspect and commit the rewritten files under ``tests/golden/``.
"""
from __future__ import annotations

import os
from pathlib import Path

import pytest

from repro import analysis
from repro.explore import cli

GOLDEN_DIR = Path(__file__).parent / "golden"
REGEN = os.environ.get("REPRO_REGEN_GOLDEN") == "1"

CASES = {
    "lint_stencil25.txt": (
        0,
        [
            "lint", "--kernel", "stencil25",
            "--config", '{"block": [32, 4, 8], "fold": [1, 1, 1]}',
            "--machine", "V100",
        ],
    ),
    "lint_fixture_racy_store.txt": (
        1,
        ["lint", "--fixture", "racy_store", "--machine", "V100"],
    ),
}


@pytest.mark.parametrize("golden_name", sorted(CASES))
def test_lint_cli_matches_golden(golden_name, capsys):
    want_rc, args = CASES[golden_name]
    analysis.clear_cache()
    rc = cli.main(args)
    out = capsys.readouterr().out
    assert rc == want_rc
    path = GOLDEN_DIR / golden_name
    if REGEN:
        path.write_text(out)
        pytest.skip(f"regenerated {path}")
    assert path.exists(), (
        f"golden file {path} missing — generate it with "
        "REPRO_REGEN_GOLDEN=1 (see module docstring)"
    )
    assert out == path.read_text(), (
        f"lint output diverged from {golden_name}; if the change is intended, "
        "regenerate with REPRO_REGEN_GOLDEN=1 and commit the diff"
    )


def test_golden_clean_and_seeded_disagree():
    clean = (GOLDEN_DIR / "lint_stencil25.txt").read_text()
    seeded = (GOLDEN_DIR / "lint_fixture_racy_store.txt").read_text()
    assert "0 error(s)" in clean.splitlines()[0]
    assert "race.write_write" in seeded and "witness" in seeded

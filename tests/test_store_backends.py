"""The pluggable store package (`repro.store`): backend dispatch, sharded
multi-writer safety, torn-write accounting, and the alias layer.

Covers the estimation-as-a-service storage contracts:

* ``open_store`` resolves paths to the right backend (file -> JSONL,
  directory -> sharded) and both backends are interchangeable views over the
  same records;
* the sharded backend survives two genuinely concurrent writer *processes*
  with zero lost records — the regression test for the multi-writer design
  goal (segment-per-writer + per-append flock);
* the single-file backend's concurrent behavior is documented, not fixed:
  complete lines always survive and torn tails are skipped, but nothing
  coordinates two writers on one file — multi-writer workloads belong on
  ``ShardedStore``;
* lazy key scans validate record *closure*: a torn tail line never counts
  toward ``len()``/``keys()`` even before any payload is materialized;
* the alias layer maps configs to fingerprints under one ``BUILDER_VERSION``
  and goes cold wholesale on a builder bump.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

import pytest

from repro.store import (
    AliasStore,
    ResultStore,
    ShardedStore,
    alias_key,
    canonical_key,
    open_store,
)

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep * bool(env.get("PYTHONPATH")) + env.get(
        "PYTHONPATH", ""
    )
    return env


# --------------------------------------------------------------------------- #
# open_store dispatch


def test_open_store_dispatch(tmp_path):
    # fresh path with .jsonl suffix -> single file
    s = open_store(tmp_path / "a.jsonl")
    assert type(s) is ResultStore
    # fresh suffix-less path -> sharded directory
    s = open_store(tmp_path / "a_dir")
    assert isinstance(s, ShardedStore)
    # existing artifacts win over the suffix heuristic
    (tmp_path / "odd.ext").write_text("")
    assert type(open_store(tmp_path / "odd.ext")) is ResultStore
    (tmp_path / "dir.jsonl").mkdir()
    assert isinstance(open_store(tmp_path / "dir.jsonl"), ShardedStore)
    # explicit backend overrides the heuristic; unknown names fail loudly
    assert isinstance(open_store(tmp_path / "b.jsonl", backend="sharded"), ShardedStore)
    with pytest.raises(ValueError, match="unknown store backend"):
        open_store(tmp_path / "c", backend="parquet")


def test_backends_are_interchangeable_views(tmp_path):
    """The same records through either backend produce identical reads."""
    recs = {canonical_key(k=i): {"x": float(i)} for i in range(8)}
    flat, shard = ResultStore(tmp_path / "f.jsonl"), ShardedStore(tmp_path / "d")
    for key, payload in recs.items():
        flat.put(key, payload, machine="V100", builder_version=3)
        shard.put(key, payload, machine="V100", builder_version=3)
    for store in (ResultStore(tmp_path / "f.jsonl"), ShardedStore(tmp_path / "d")):
        assert len(store) == len(recs)
        assert {k: store.get(k) for k in store.keys()} == recs
        assert store.machines() == {"V100": len(recs)}
        assert store.builder_versions() == {3: len(recs)}


# --------------------------------------------------------------------------- #
# torn-write accounting (lazy scan must validate closure, not just keys)


def test_lazy_len_and_keys_exclude_torn_lines(tmp_path):
    """A killed writer can leave a line whose key parses but whose payload is
    cut short.  The lazy key scan must not count it — ``len()``/``keys()``
    agree with what ``get()`` can actually serve, *without* materializing."""
    p = tmp_path / "r.jsonl"
    s = ResultStore(p)
    s.put("a", {"v": 1})
    s.put("b", {"v": 2})
    with p.open("a") as f:
        # complete key, torn payload: the pre-fix scanner counted all of these
        f.write('{"key": "c", "payload": {"x": 1\n')
        f.write('{"key": "d", "payload": {"s": "un')  # torn inside a string
    s2 = ResultStore(p)
    assert len(s2) == 2
    assert set(s2.keys()) == {"a", "b"}
    assert "c" not in s2 and "d" not in s2
    assert s2.get("a") == {"v": 1} and s2.get("b") == {"v": 2}


def test_torn_line_followed_by_good_writer_recovers_the_good_line(tmp_path):
    """Sharded layout: one writer dies mid-append, another keeps going in its
    own segment — the survivor's records load fine."""
    d = tmp_path / "store"
    w1 = ShardedStore(d, writer_id="w1")
    w1.put("a", {"v": 1})
    with w1.segment_path.open("a") as f:
        f.write('{"key": "torn", "payload": {"x": ')
    w2 = ShardedStore(d, writer_id="w2")
    w2.put("b", {"v": 2})
    fresh = ShardedStore(d, writer_id="reader")
    assert len(fresh) == 2 and set(fresh.keys()) == {"a", "b"}


# --------------------------------------------------------------------------- #
# concurrent writers


_WRITER = """
import sys
from repro.store import ShardedStore, ResultStore, canonical_key

cls = ShardedStore if sys.argv[2] == "sharded" else ResultStore
kw = {"writer_id": sys.argv[3]} if sys.argv[2] == "sharded" else {}
store = cls(sys.argv[1], **kw)
who, n = sys.argv[3], int(sys.argv[4])
for i in range(n):
    store.put(canonical_key(w=who, i=i), {"writer": who, "i": i})
print("done", who)
"""


def _run_writers(path, backend, n_per_writer):
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", _WRITER, str(path), backend, who, str(n_per_writer)],
            env=_env(),
            stdout=subprocess.PIPE,
            stderr=subprocess.PIPE,
        )
        for who in ("alpha", "beta")
    ]
    for p in procs:
        out, err = p.communicate(timeout=120)
        assert p.returncode == 0, err.decode()


def test_sharded_store_two_concurrent_writers_lose_no_records(tmp_path):
    """THE multi-writer regression test: two processes, one store directory,
    400 interleaved appends — every record must survive."""
    d = tmp_path / "store"
    n = 200
    _run_writers(d, "sharded", n)
    store = ShardedStore(d, writer_id="reader")
    assert len(store) == 2 * n
    for who in ("alpha", "beta"):
        for i in range(n):
            assert store.get(canonical_key(w=who, i=i)) == {"writer": who, "i": i}
    # two writers -> two segments (reader hasn't appended)
    segs = store.segments()
    assert set(segs) == {"segment-alpha.jsonl", "segment-beta.jsonl"}
    assert all(count == n for count in segs.values())


def test_sharded_store_shared_writer_id_still_serializes(tmp_path):
    """A reused writer id degrades to one shared segment; the per-append flock
    still keeps every line whole."""
    d = tmp_path / "store"

    procs = [
        subprocess.Popen(
            [sys.executable, "-c", _WRITER, str(d), "sharded", "same", "120"],
            env=_env(),
            stdout=subprocess.PIPE,
            stderr=subprocess.PIPE,
        )
        for _ in range(2)
    ]
    for p in procs:
        out, err = p.communicate(timeout=120)
        assert p.returncode == 0, err.decode()
    store = ShardedStore(d, writer_id="reader")
    # both wrote the same 120 keys (same payloads): last write wins -> 120 live
    assert len(store) == 120
    assert store.segments() == {"segment-same.jsonl": 240}


def test_single_file_concurrent_writers_documented_behavior(tmp_path):
    """Documentation, not endorsement: ``ResultStore`` appends are single
    buffered writes with no cross-process coordination.  Every line that
    reaches disk *complete* is served and torn tails are skipped — but nothing
    prevents two writers interleaving partial lines under memory pressure, so
    concurrent multi-writer workloads belong on ``ShardedStore`` (which this
    suite proves lossless above)."""
    p = tmp_path / "shared.jsonl"
    _run_writers(p, "jsonl", 60)
    store = ResultStore(p)
    # closed lines parse; anything torn by interleaving would be skipped, so
    # the live count can never EXCEED what the writers wrote
    assert len(store) <= 120
    for key in store.keys():
        assert store.get(key) is not None


# --------------------------------------------------------------------------- #
# sharded compaction


def test_sharded_compact_folds_segments_and_preserves_records(tmp_path):
    d = tmp_path / "store"
    w1 = ShardedStore(d, writer_id="w1")
    w2 = ShardedStore(d, writer_id="w2")
    w1.put("a", {"v": 1})
    w2.put("a", {"v": 2})  # supersedes across segments (name-sorted replay)
    w2.put("b", {"v": 3})
    w1.compact()
    assert (d / "compacted.jsonl").exists()
    assert set(ShardedStore(d).segments()) == {"compacted.jsonl"}
    fresh = ShardedStore(d, writer_id="w3")
    assert len(fresh) == 2
    assert fresh.get("a") == {"v": 2} and fresh.get("b") == {"v": 3}
    # appends after compaction land in a fresh segment and replay on top
    fresh.put("a", {"v": 9})
    assert ShardedStore(d).get("a") == {"v": 9}


def test_sharded_compact_spares_segments_written_mid_compaction(tmp_path):
    """A segment that appears between layer capture and unlink must survive
    (writers don't take the compaction lock)."""
    d = tmp_path / "store"
    w = ShardedStore(d, writer_id="w")
    w.put("a", {"v": 1})

    class RacingStore(ShardedStore):
        def _live_record_lines(self):
            # a new writer lands a record while compaction is folding
            late = ShardedStore(d, writer_id="late")
            late.put("z", {"v": 26})
            yield from super()._live_record_lines()

    RacingStore(d, writer_id="w").compact()
    survivors = ShardedStore(d, writer_id="reader")
    assert survivors.get("a") == {"v": 1} and survivors.get("z") == {"v": 26}
    assert "segment-late.jsonl" in survivors.segments()


# --------------------------------------------------------------------------- #
# alias layer


def test_alias_store_roundtrip_and_builder_bump(tmp_path, monkeypatch):
    from repro.frontend import ir as ir_mod

    a = AliasStore(tmp_path / "alias.jsonl")
    key = alias_key("stencil25", "gpu", {"block": (32, 8, 4)})
    assert a.get(key) is None
    a.put(key, "f" * 64)
    assert a.get(key) == "f" * 64
    assert AliasStore(tmp_path / "alias.jsonl").get(key) == "f" * 64  # durable
    # wholesale invalidation: a builder bump makes every entry read as a miss
    monkeypatch.setattr(ir_mod, "BUILDER_VERSION", ir_mod.BUILDER_VERSION + 1)
    assert a.get(key) is None
    # re-recording under the new version repopulates; compact() drops the
    # stale generation from disk
    a.put(key, "e" * 64)
    assert a.get(key) == "e" * 64
    a.compact()
    lines = [json.loads(x) for x in (tmp_path / "alias.jsonl").read_text().splitlines()]
    assert len(lines) == 1 and lines[0]["fp"] == "e" * 64


def test_alias_key_is_spelling_sensitive_by_design(tmp_path):
    """The alias keys the *config identity*, not the IR: respelled configs
    (list vs tuple blocks) miss the alias and fall back to tracing, which
    still converges on one store entry via the fingerprint."""
    k1 = alias_key("stencil25", "gpu", {"block": (32, 8, 4)})
    k2 = alias_key("stencil25", "gpu", {"block": [32, 8, 4]})
    k3 = alias_key("stencil25", "gpu", {"block": (32, 8, 5)})
    assert k1 == k2  # canonical_key folds list/tuple
    assert k1 != k3


# --------------------------------------------------------------------------- #
# retention: TTL + record-count eviction


def test_ttl_expired_hits_read_as_misses(tmp_path):
    p = tmp_path / "s.jsonl"
    ResultStore(p).put("old", {"v": 1}, ts=time.time() - 3600)
    s = open_store(p, max_age_s=60)
    s.put("new", {"v": 2})
    assert s.get("old") is None  # expired hit is a miss (and drops)
    assert "old" not in s
    assert s.get("new") == {"v": 2}


def test_ttl_treats_legacy_ts_less_records_as_infinitely_old(tmp_path):
    p = tmp_path / "s.jsonl"
    p.write_text(json.dumps({"key": "legacy", "payload": {"v": 1}}) + "\n")
    assert ResultStore(p).get("legacy") == {"v": 1}  # no policy: still served
    assert open_store(p, max_age_s=10**9).get("legacy") is None


def test_max_records_evicts_oldest_keeping_newest_generation(tmp_path):
    for store in (
        open_store(tmp_path / "f.jsonl", max_records=3),
        open_store(tmp_path / "d", max_records=3),
    ):
        t0 = time.time() - 100
        for i in range(5):
            store.put(f"k{i}", {"v": i}, ts=t0 + i)
        assert len(store) == 3
        assert set(store.keys()) == {"k2", "k3", "k4"}
        # overwriting an old key with a newer ts refreshes it past eviction
        store.put("k2", {"v": 22}, ts=t0 + 50)
        store.put("k5", {"v": 5}, ts=t0 + 6)
        store.put("k6", {"v": 6}, ts=t0 + 7)
        assert "k2" in store and store.get("k2") == {"v": 22}
        assert "k3" not in store


def test_compact_ttl_shrinks_disk_for_both_backends(tmp_path):
    paths = (tmp_path / "f.jsonl", tmp_path / "d")
    for path in paths:
        store = open_store(path)
        store.put("stale", {"v": 0}, ts=time.time() - 3600)
        store.put("fresh", {"v": 1})
        store.compact(ttl_s=60)
    for path in paths:
        fresh = open_store(path)
        assert fresh.get("stale") is None
        assert fresh.get("fresh") == {"v": 1}
        assert len(fresh) == 1
    # the stale record is gone from disk, not just the in-memory view
    assert "stale" not in (tmp_path / "f.jsonl").read_text()


def test_retention_rejects_nonsense_policies(tmp_path):
    with pytest.raises(ValueError):
        open_store(tmp_path / "a.jsonl", max_age_s=0)
    with pytest.raises(ValueError):
        open_store(tmp_path / "b.jsonl", max_records=0)
